"""Benchmark orchestrator — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--fast] [--only NAME]

Prints ``name,us_per_call,derived`` CSV rows (stdout) and writes JSON
artifacts to experiments/. The roofline module reads the dry-run output if
present (run repro.launch.dryrun first for the full §Roofline table).
"""

from __future__ import annotations

import argparse
import sys
import time

MODULES = [
    "table_k_sweep",      # paper Tables 1-3
    "table_fpr_fnr",      # paper Tables 4-9
    "fig_convergence",    # paper Figs 2-10
    "fig_stability",      # paper Fig 11
    "theory_convergence", # Theorem 3.1 / Lemma 1 + Eq-level checks
    "throughput",         # §1 ingest-rate requirement; engines + kernels
    "counter_throughput", # SBF counter planes vs dense8 (DESIGN §3.6)
    "window_throughput",  # swbf sliding window vs dense8 idiom (DESIGN §3.7)
    "template_throughput",  # templated steps vs frozen baselines (§3.8)
    "blocked_accuracy",   # beyond-paper: VMEM-blocked layout FPR cost
    "roofline",           # §Roofline terms from the dry-run artifacts
]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="1/4-length streams (CI mode)")
    ap.add_argument("--only", default=None)
    args = ap.parse_args(argv)

    print("name,us_per_call,derived")
    failures = 0
    for name in MODULES:
        if args.only and args.only != name:
            continue
        # release accumulated jitted executables between modules — hundreds
        # of distinct DedupConfig compilations otherwise exhaust the LLVM
        # JIT arena on long runs
        import jax
        from repro.core.engine import _cached_engine
        _cached_engine.cache_clear()
        jax.clear_caches()
        mod = __import__(f"benchmarks.{name}", fromlist=["main"])
        t0 = time.perf_counter()
        try:
            rows = mod.main(fast=args.fast)
        except Exception as e:                   # noqa: BLE001
            print(f"{name},0,ERROR:{type(e).__name__}:{e}")
            failures += 1
            continue
        for r in rows:
            print(r)
        print(f"{name}/__total__,{(time.perf_counter()-t0)*1e6:.0f},ok")
        sys.stdout.flush()
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
