"""Paper Tables 4-9: all five algorithms, memory 64..512MB × distinct
{15%, 60%, 90%}, 695M/1B records — at 1/256 scale (ratios held).

The validation targets (paper §6.3): (i) FNR ordering
SBF >> RSBF > BSBF > BSBFSD > RLBSBF at every cell, (ii) comparable FPR
(same order of magnitude at >=128MB-equivalent), (iii) FNR improvements
growing with memory (the 2x..300x headline).
"""

from __future__ import annotations

from repro.core import DedupConfig
from repro.configs.paper_dedup import scaled_config

from .common import csv_row, run_stream_measured, save_artifact, stream

MEMORIES_MB = (64, 128, 256, 512)
DISTINCTS = (0.15, 0.60, 0.90)
N_RECORDS = 695_000_000 // 256
VARIANTS = ("sbf", "rsbf", "bsbf", "bsbfsd", "rlbsbf")


def main(fast: bool = False) -> list:
    import jax
    n = N_RECORDS // (4 if fast else 1)
    rows, out = [], {}
    for distinct in DISTINCTS:
        keys, truth = stream(n, distinct)
        for mem_mb in MEMORIES_MB:
            jax.clear_caches()                  # bound the LLVM JIT arena
            cell = {}
            for variant in VARIANTS:
                cfg = scaled_config(variant, mem_mb, batch_size=8192)
                r = run_stream_measured(cfg, keys, truth, n_windows=1)
                cell[variant] = {"fpr": r["fpr"], "fnr": r["fnr"],
                                 "eps": r["throughput_eps"]}
                tag = f"table_fpr_fnr/d{int(distinct*100)}/mem{mem_mb}MB/{variant}"
                rows.append(csv_row(
                    tag, r["us_per_elem"],
                    f"FPR%={r['fpr']*100:.3f};FNR%={r['fnr']*100:.3f}"))
            imp = (cell["sbf"]["fnr"] + 1e-9) / (cell["rlbsbf"]["fnr"] + 1e-9)
            cell["rlbsbf_fnr_improvement_x"] = imp
            out[f"d{int(distinct*100)}/mem{mem_mb}MB"] = cell
    save_artifact("table_fpr_fnr", out)
    return rows


if __name__ == "__main__":
    print("\n".join(main()))
