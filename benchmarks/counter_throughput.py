"""SBF counter-layout throughput: dense8 vs planes vs fused Pallas.

    PYTHONPATH=src python -m benchmarks.counter_throughput [--fast]

The counter-plane layout (DESIGN.md §3.6) exists to make the paper's SBF
baseline a first-class citizen of the packed fast path. This sweep measures
SBF ingest throughput per layout at three filter sizes:

  * ``mem_21`` (256 KB)  — container-scale, event costs dominate;
  * ``mem_23`` (1 MB)    — the crossover regime;
  * ``mem_26`` (8 MB)    — the paper's smallest table (§6), where dense8's
    O(s) per-batch cell passes dominate and the 32x-denser word layout pays
    off. This is the row ``scripts/bench_check.py --counter`` gates on:
    planes must hold >= 2x dense8 elems/s.

The fused Pallas row runs interpret mode off-TPU (python-level correctness
path) on a short prefix at the small size only — informational, never gated,
same policy as ``benchmarks/throughput.py``.

Emits ``BENCH_counter.json`` at the repo root in the same baseline/current
shape as the other BENCH artifacts: ``baseline`` freezes at first capture
(the regression anchor), ``current`` refreshes every run.
"""

from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Dedup, DedupConfig

from .common import csv_row, save_artifact, stream

BENCH_PATH = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                          "BENCH_counter.json"))
MEM_SWEEP = (1 << 21, 1 << 23, 1 << 26)
GATE_MEM = 1 << 26          # the paper-scale row the 2x gate applies to


def _measure_stream(cfg: DedupConfig, jkeys: jnp.ndarray, reps: int = 3
                    ) -> dict:
    n = int(jkeys.shape[0])
    d = Dedup(cfg)
    _st, dup = d.run_stream(d.init(), jkeys)    # compile at full shape
    np.asarray(dup)
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        _st, dup = d.run_stream(d.init(), jkeys)
        np.asarray(dup)
        best = min(best, time.perf_counter() - t0)
    return {"eps": n / best, "us_per_elem": best / n * 1e6}


def measure_counter_engines(fast: bool = True) -> dict:
    n = 500_000 // (4 if fast else 1)
    keys, _truth = stream(n, 0.6, seed=9)
    jkeys = jnp.asarray(keys)
    out = {}
    for mem in MEM_SWEEP:
        tag = f"mem_{mem.bit_length() - 1}"
        base = dict(memory_bits=mem, batch_size=8192)
        d8 = _measure_stream(
            DedupConfig.for_variant("sbf", **base), jkeys)
        pl = _measure_stream(
            DedupConfig.for_variant("sbf", layout="planes", **base), jkeys)
        out[f"{tag}/sbf_dense8"] = d8
        out[f"{tag}/sbf_planes"] = pl
        out[f"{tag}/planes_speedup"] = pl["eps"] / d8["eps"]
    # fused kernel: interpret off-TPU — short prefix, small filter, info-only
    pk = _measure_stream(
        DedupConfig.for_variant("sbf", memory_bits=1 << 18, batch_size=8192,
                                layout="planes", backend="pallas"),
        jkeys[:32_768])
    pk["interpret"] = jax.default_backend() != "tpu"
    out["sbf_planes_pallas"] = pk
    return out


def write_counter_artifact(current: dict, meta: dict) -> str:
    prev = {}
    if os.path.exists(BENCH_PATH):
        with open(BENCH_PATH) as f:
            prev = json.load(f)
    baseline = prev.get("baseline")
    if baseline is None:
        baseline = dict(current, baseline_seeded_from_current=True)
    doc = {"schema": 1, "baseline": baseline, "current": current,
           "meta": meta}
    with open(BENCH_PATH, "w") as f:
        json.dump(doc, f, indent=1)
    return BENCH_PATH


def main(fast: bool = False) -> list:
    out = measure_counter_engines(fast=fast)
    rows = []
    for name, stats in out.items():
        if isinstance(stats, dict) and "eps" in stats:
            rows.append(csv_row(f"counter/{name}", 1e6 / stats["eps"],
                                f"elems_per_s={stats['eps']:.0f}"))
        elif isinstance(stats, float):
            rows.append(csv_row(f"counter/{name}", 0.0, f"x={stats:.2f}"))
    save_artifact("counter_throughput", out)
    path = write_counter_artifact(
        out, meta={"fast": fast, "backend": jax.default_backend(),
                   "captured": time.strftime("%Y-%m-%d")})
    rows.append(csv_row("counter/artifact", 0.0, path))
    return rows


if __name__ == "__main__":
    fast = "--fast" in __import__("sys").argv
    print("\n".join(main(fast=fast)))
