"""Sketch-template throughput gate (DESIGN.md §3.8).

    PYTHONPATH=src python -m benchmarks.template_throughput

The §3.8 refactor replaced every hand-written per-variant step with two
spec-driven generators. This emitter re-measures the TEMPLATED engines at
exactly the workload points the historical artifacts froze — the
``batched_packed`` row of ``BENCH_throughput.json`` (rlbsbf), the
paper-scale ``mem_26/sbf_planes`` row of ``BENCH_counter.json`` and the
``mem_26/swbf_planes`` row of ``BENCH_window.json`` — and records
``ratio = eps / ref_eps`` against those frozen pre-template numbers.
``scripts/bench_check.py --template`` gates the committed ratios at
>= 0.95: the template abstraction may cost at most 5% elems/s versus the
code it replaced. The two counting sketches (cms/hh) have no historical
twin — their rows are recorded as the trajectory anchor for future PRs
(eps > 0 and the one-dispatch contract are still checked).

Emits ``BENCH_template.json`` at the repo root in the same
baseline/current shape as the other BENCH artifacts.
"""

from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Dedup, DedupConfig

from .common import csv_row, save_artifact, stream

BENCH_PATH = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                          "BENCH_template.json"))
GATE_RATIO = 0.95           # templated step >= 95% of the frozen baseline

# row -> (engine config, stream length, (ref artifact, ref row key)).
# The stream lengths replicate the capture conditions of each frozen row.
ROWS = {
    "rlbsbf_packed": (
        dict(variant="rlbsbf", memory_bits=1 << 21, batch_size=8192,
             packed=True),
        500_000, ("BENCH_throughput.json", "batched_packed")),
    "sbf_planes": (
        dict(variant="sbf", memory_bits=1 << 26, batch_size=8192,
             layout="planes"),
        500_000, ("BENCH_counter.json", "mem_26/sbf_planes")),
    "swbf_planes": (
        dict(variant="swbf", memory_bits=1 << 26, batch_size=8192,
             window=8),
        125_000, ("BENCH_window.json", "mem_26/swbf_planes")),
    # the counting sketches are NEW template instances — no frozen twin;
    # recorded as this artifact's own trajectory anchor
    "cms": (dict(variant="cms", memory_bits=1 << 23, batch_size=8192),
            250_000, None),
    "hh": (dict(variant="hh", memory_bits=1 << 23, batch_size=8192),
           250_000, None),
}
GATED_ROWS = tuple(k for k, v in ROWS.items() if v[2] is not None)
_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def _ref_eps(ref) -> float | None:
    if ref is None:
        return None
    fname, key = ref
    path = os.path.join(_ROOT, fname)
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f).get("current", {}).get(key, {}).get("eps")


def _measure_stream(cfg: DedupConfig, jkeys: jnp.ndarray, reps: int = 3
                    ) -> dict:
    n = int(jkeys.shape[0])
    d = Dedup(cfg)
    _st, dup = d.run_stream(d.init(), jkeys)    # compile at full shape
    np.asarray(dup)
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        _st, dup = d.run_stream(d.init(), jkeys)
        np.asarray(dup)
        best = min(best, time.perf_counter() - t0)
    return {"eps": n / best, "us_per_elem": best / n * 1e6,
            "stream_cache": d.stream_cache_size()}


def measure_template_engines(fast: bool = True) -> dict:
    # the stream length per row is part of the capture conditions the ratio
    # depends on — --fast trims repetitions, never the workload
    out = {}
    for name, (kw, n, ref) in ROWS.items():
        keys, _truth = stream(n, 0.6, seed=9)
        rec = _measure_stream(DedupConfig(**kw).validate(),
                              jnp.asarray(keys), reps=2 if fast else 3)
        ref_eps = _ref_eps(ref)
        if ref_eps:
            rec["ref_eps"] = ref_eps
            rec["ratio"] = rec["eps"] / ref_eps
        out[name] = rec
    return out


def write_template_artifact(current: dict, meta: dict) -> str:
    prev = {}
    if os.path.exists(BENCH_PATH):
        with open(BENCH_PATH) as f:
            prev = json.load(f)
    baseline = prev.get("baseline")
    if baseline is None:
        baseline = dict(current, baseline_seeded_from_current=True)
    doc = {"schema": 1, "baseline": baseline, "current": current,
           "meta": meta}
    with open(BENCH_PATH, "w") as f:
        json.dump(doc, f, indent=1)
    return BENCH_PATH


def main(fast: bool = False) -> list:
    out = measure_template_engines(fast=fast)
    rows = []
    for name, stats in out.items():
        extra = (f" ratio={stats['ratio']:.2f}" if "ratio" in stats else "")
        rows.append(csv_row(f"template/{name}", 1e6 / stats["eps"],
                            f"elems_per_s={stats['eps']:.0f}{extra}"))
    save_artifact("template_throughput", out)
    path = write_template_artifact(
        out, meta={"fast": fast, "backend": jax.default_backend(),
                   "captured": time.strftime("%Y-%m-%d")})
    rows.append(csv_row("template/artifact", 0.0, path))
    return rows


if __name__ == "__main__":
    fast = "--fast" in __import__("sys").argv
    print("\n".join(main(fast=fast)))
