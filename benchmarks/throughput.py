"""Ingest throughput (the paper's §1 'real-time processing at 1 GB/sec'
requirement): elements/s of the sequential oracle vs the batched engine vs
the packed/kernels paths, plus the per-op cost of the Pallas kernels in
interpret mode. The batched-vs-scan ratio is the TPU-adaptation headline
(DESIGN.md §3.1).

Emits ``BENCH_throughput.json`` at the repo root — the perf trajectory
artifact ``scripts/bench_check.py`` regresses against. The file's
``baseline`` section is the *seed* engine's numbers (captured once, PR 1)
and is never overwritten; ``current`` is refreshed on every run.
"""

from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Dedup, DedupConfig
from repro.core.hashing import derive_seeds
from repro.core.packed import split_pos
from repro.kernels import ops

from .common import csv_row, save_artifact, stream

BENCH_PATH = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                          "BENCH_throughput.json"))


def _time(fn, *args, reps=3):
    fn(*args)                                   # warm-up/compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps


def _measure_stream(cfg: DedupConfig, jkeys: jnp.ndarray, reps: int = 3
                    ) -> dict:
    """elems/s of ``run_stream`` over the whole stream; warm-up uses the SAME
    length so the timed runs exercise the cached compiled scan, not tracing.
    Best-of-``reps`` — wall-clock on shared CPUs jitters far more than the
    engine does."""
    n = int(jkeys.shape[0])
    d = Dedup(cfg)
    _st, dup = d.run_stream(d.init(), jkeys)    # compile at full shape
    np.asarray(dup)
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        _st, dup = d.run_stream(d.init(), jkeys)
        np.asarray(dup)
        best = min(best, time.perf_counter() - t0)
    return {"eps": n / best, "us_per_elem": best / n * 1e6}


def write_bench_artifact(current: dict, meta: dict) -> str:
    prev = {}
    if os.path.exists(BENCH_PATH):
        with open(BENCH_PATH) as f:
            prev = json.load(f)
    baseline = prev.get("baseline")
    if baseline is None:
        # the committed artifact should always exist (it is tracked); seeding
        # the anchor from the CURRENT engine makes every later "vs baseline"
        # ratio ~1x, so say so loudly and mark the provenance
        import sys
        print("throughput: BENCH_throughput.json had no baseline — seeding "
              "it from the CURRENT engine (restore the committed artifact "
              "for a meaningful seed-engine anchor)", file=sys.stderr)
        baseline = dict(current, baseline_seeded_from_current=True)
    doc = {
        "schema": 1,
        # the seed engine's numbers — frozen once, the regression anchor
        "baseline": baseline,
        "current": current,
        "meta": meta,
    }
    with open(BENCH_PATH, "w") as f:
        json.dump(doc, f, indent=1)
    return BENCH_PATH


def measure_engines(fast: bool = True, pallas_n: int | None = None) -> dict:
    """The four trajectory engines: dense8, packed-jnp, packed-pallas
    (interpret off-TPU), sequential oracle."""
    n = 2_000_000 // (4 if fast else 1)
    keys, _truth = stream(n, 0.6, seed=9)
    jkeys = jnp.asarray(keys)
    out = {}
    out["batched_dense8"] = _measure_stream(
        DedupConfig.for_variant("rlbsbf", memory_bits=1 << 21,
                                batch_size=8192), jkeys)
    out["batched_packed"] = _measure_stream(
        DedupConfig.for_variant("rlbsbf", memory_bits=1 << 21,
                                batch_size=8192, packed=True), jkeys)
    # fused Pallas step: interpret mode off-TPU is a correctness-path cost
    # (python-level interpreter), so measure a short prefix only
    np_ = pallas_n if pallas_n is not None else 65_536
    out["batched_packed_pallas"] = _measure_stream(
        DedupConfig.for_variant("rlbsbf", memory_bits=1 << 18,
                                batch_size=8192, packed=True,
                                backend="pallas"), jkeys[:np_])
    out["batched_packed_pallas"]["interpret"] = \
        jax.default_backend() != "tpu"
    # sequential oracle on a small prefix (it is the semantics oracle,
    # not the production path)
    n_seq = 50_000
    d = Dedup(DedupConfig.for_variant("rlbsbf", memory_bits=1 << 16))
    _, dup = d.run_stream_oracle(d.init(), jkeys[:n_seq])      # compile
    np.asarray(dup)
    t0 = time.perf_counter()
    _, dup = d.run_stream_oracle(d.init(), jkeys[:n_seq])
    np.asarray(dup)
    dt = time.perf_counter() - t0
    out["oracle_scan"] = {"eps": n_seq / dt}
    out["batched_speedup_vs_scan"] = (out["batched_dense8"]["eps"] /
                                      out["oracle_scan"]["eps"])
    return out


def main(fast: bool = False) -> list:
    rows = []
    out = measure_engines(fast=fast)
    for name in ("batched_dense8", "batched_packed", "batched_packed_pallas",
                 "oracle_scan"):
        eps = out[name]["eps"]
        rows.append(csv_row(f"throughput/{name}", 1e6 / eps,
                            f"elems_per_s={eps:.0f}"))
    rows.append(csv_row("throughput/batched_speedup", 0.0,
                        f"x={out['batched_speedup_vs_scan']:.1f}"))

    # kernel micro-benchmarks (interpret mode on CPU — correctness-path cost;
    # TPU perf is modeled in §Roofline, not measured here)
    n = 2_000_000 // (4 if fast else 1)
    keys, _ = stream(n, 0.6, seed=9)          # _STREAM_CACHE hit — no regen
    b, k, s = 8192, 2, 1 << 20
    kk = jnp.asarray(keys[:b])                # transfer only the slice
    seeds = derive_seeds(1, k)
    dt = _time(lambda: ops.hash_positions(kk, seeds, s))
    rows.append(csv_row("kernel/hashmix_interpret", dt / b * 1e6,
                        f"batch={b}"))
    words = jnp.zeros((k, s // 32), jnp.uint32)
    pos = ops.hash_positions(kk, seeds, s)
    widx, mask = split_pos(pos)
    dt = _time(lambda: ops.probe(words, widx, mask))
    rows.append(csv_row("kernel/bloom_probe_interpret", dt / b * 1e6,
                        f"batch={b}"))
    save_artifact("throughput", out)
    path = write_bench_artifact(
        out, meta={"n": n, "fast": fast, "backend": jax.default_backend(),
                   "captured": time.strftime("%Y-%m-%d")})
    rows.append(csv_row("throughput/artifact", 0.0, path))
    return rows


if __name__ == "__main__":
    print("\n".join(main()))
