"""Ingest throughput (the paper's §1 'real-time processing at 1 GB/sec'
requirement): elements/s of the sequential oracle vs the batched engine vs
the packed/kernels path, plus the per-op cost of the Pallas kernels in
interpret mode. The batched-vs-scan ratio is the TPU-adaptation headline
(DESIGN.md §3.1)."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Dedup, DedupConfig
from repro.core.hashing import derive_seeds
from repro.core.packed import split_pos
from repro.kernels import ops

from .common import csv_row, save_artifact, stream


def _time(fn, *args, reps=3):
    fn(*args)                                   # warm-up/compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps


def main(fast: bool = False) -> list:
    rows, out = [], {}
    n = 2_000_000 // (4 if fast else 1)
    keys, truth = stream(n, 0.6, seed=9)
    jkeys = jnp.asarray(keys)

    for name, cfg in [
        ("batched_dense8", DedupConfig.for_variant(
            "rlbsbf", memory_bits=1 << 21, batch_size=8192)),
        ("batched_packed", DedupConfig.for_variant(
            "rlbsbf", memory_bits=1 << 21, batch_size=8192, packed=True)),
    ]:
        d = Dedup(cfg)
        st = d.init()
        st, _ = d.run_stream(st, jkeys[:cfg.batch_size * 2])   # compile
        t0 = time.perf_counter()
        _st, dup = d.run_stream(d.init(), jkeys)
        np.asarray(dup)
        dt = time.perf_counter() - t0
        eps = n / dt
        out[name] = {"eps": eps, "us_per_elem": dt / n * 1e6}
        rows.append(csv_row(f"throughput/{name}", dt / n * 1e6,
                            f"elems_per_s={eps:.0f}"))

    # sequential oracle on a small prefix (it is the semantics oracle,
    # not the production path)
    n_seq = 50_000
    cfg = DedupConfig.for_variant("rlbsbf", memory_bits=1 << 16)
    d = Dedup(cfg)
    st, _ = d.run_stream_oracle(d.init(), jkeys[:1000])        # compile
    t0 = time.perf_counter()
    _, dup = d.run_stream_oracle(d.init(), jkeys[:n_seq])
    np.asarray(dup)
    dt = time.perf_counter() - t0
    out["oracle_scan"] = {"eps": n_seq / dt}
    rows.append(csv_row("throughput/oracle_scan", dt / n_seq * 1e6,
                        f"elems_per_s={n_seq/dt:.0f}"))
    out["batched_speedup_vs_scan"] = out["batched_dense8"]["eps"] / \
        out["oracle_scan"]["eps"]
    rows.append(csv_row(
        "throughput/batched_speedup", 0.0,
        f"x={out['batched_speedup_vs_scan']:.1f}"))

    # kernel micro-benchmarks (interpret mode on CPU — correctness-path cost;
    # TPU perf is modeled in §Roofline, not measured here)
    b, k, s = 8192, 2, 1 << 20
    kk = jkeys[:b]
    seeds = derive_seeds(1, k)
    dt = _time(lambda: ops.hash_positions(kk, seeds, s))
    rows.append(csv_row("kernel/hashmix_interpret", dt / b * 1e6,
                        f"batch={b}"))
    words = jnp.zeros((k, s // 32), jnp.uint32)
    pos = ops.hash_positions(kk, seeds, s)
    widx, mask = split_pos(pos)
    dt = _time(lambda: ops.probe(words, widx, mask))
    rows.append(csv_row("kernel/bloom_probe_interpret", dt / b * 1e6,
                        f"batch={b}"))
    save_artifact("throughput", out)
    return rows


if __name__ == "__main__":
    print("\n".join(main()))
