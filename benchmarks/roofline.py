"""§Roofline: derive the three roofline terms per (arch × shape × mesh) from
the dry-run's compiled artifacts (experiments/dryrun.json).

    compute term    = HLO_FLOPs / (peak bf16 FLOP/s)          [per device]
    memory term     = HLO_bytes / HBM bandwidth               [per device]
    collective term = collective_bytes / ICI link bandwidth   [per device]

plus MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D (MoE) per device and the
useful-compute ratio MODEL_FLOPS / HLO_FLOPs. The dominant term is the
bottleneck §Perf iterates on. cost_analysis numbers come from the per-device
SPMD module, so no further division by chip count is needed.
"""

from __future__ import annotations

import json
import os

import numpy as np

from repro.compat import normalize_cost_analysis
from repro.configs import get_arch
from repro.launch.hw import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

from .common import ART_DIR, csv_row, save_artifact

DRYRUN_PATH = os.path.join(ART_DIR, "dryrun.json")


def model_flops_per_device(rec: dict) -> float | None:
    """6·N·D (training incl. backward) / 2·N·D (inference) per device."""
    arch_id = rec["arch"]
    if arch_id == "dedup-stream":
        return None
    arch = get_arch(arch_id)
    n_chips = rec.get("n_chips") or int(np.prod(list(rec["mesh_shape"].values())))
    dims = rec["dims"]
    if arch.family == "lm":
        n_active = arch.cfg.active_param_count()
        if rec["kind"] == "train":
            tokens = dims["batch"] * dims["seq"]
            return 6.0 * n_active * tokens / n_chips
        if rec["kind"] == "prefill":
            tokens = dims["batch"] * dims["seq"]
            return 2.0 * n_active * tokens / n_chips
        # decode: one token per sequence + attention over the cache
        tokens = dims["batch"]
        return 2.0 * n_active * tokens / n_chips
    if arch.family == "gnn":
        # per edge: edge MLP (3d->d->d), per node: node MLP (2d->d->d), x L
        cfg = arch.cfg_for(rec["shape"])
        d = cfg.d_hidden
        per_edge = 2 * (3 * d * d + d * d)
        per_node = 2 * (2 * d * d + d * d)
        f = cfg.n_layers * (dims["n_edges"] * per_edge +
                            dims["n_nodes"] * per_node)
        mult = 3.0 if rec["kind"] == "train" else 1.0
        return mult * f / n_chips
    if arch.family == "recsys":
        cfg = arch.cfg
        d_in = cfg.n_sparse * cfg.embed_dim + cfg.n_dense
        mlp = 0
        dims_list = [d_in, *cfg.mlp_dims]
        for a, b in zip(dims_list[:-1], dims_list[1:]):
            mlp += 2 * a * b
        batch = dims.get("batch", 1)
        mult = 3.0 if rec["kind"] == "train" else 1.0
        if rec["kind"] == "retrieval":
            return 2.0 * dims["n_cand"] * cfg.embed_dim / n_chips
        return mult * batch * mlp / n_chips
    return None


def roofline_terms(rec: dict) -> dict:
    """Terms from the loop-aware HLO model (launch/analysis.py): XLA's flat
    cost_analysis counts while bodies once, so scanned models (layers /
    grad-accum / attention blocks) need the trip-count-corrected numbers."""
    la = rec.get("loop_aware")
    if la:
        flops = la["flops"]
        # essential = dot/gather/DUS/copy/collective traffic (TPU-grade
        # fusion); plain hbm_bytes (every instruction boundary) is the
        # no-fusion upper bracket, reported alongside.
        bytes_acc = la.get("hbm_bytes_essential", la["hbm_bytes"])
        coll = la["collectives_bytes"].get("total", 0)
    else:   # legacy records — possibly raw cost_analysis() payloads written
            # by a drifted dryrun (list-of-dicts on jax 0.4.x); normalize
        cost = normalize_cost_analysis(rec.get("cost"))
        flops = cost.get("flops", 0.0)
        bytes_acc = cost.get("bytes_accessed",
                             cost.get("bytes accessed", 0.0))
        coll = rec.get("collectives_bytes", {}).get("total", 0)
    t_compute = flops / PEAK_FLOPS_BF16
    t_memory = bytes_acc / HBM_BW
    t_coll = coll / ICI_BW
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_coll}
    dom = max(terms, key=terms.get)
    bound = max(terms.values())
    mf = model_flops_per_device(rec)
    out = {
        **terms, "dominant": dom,
        "roofline_bound_s": bound,
        "model_flops_per_device": mf,
        "useful_compute_ratio": (mf / flops) if (mf and flops) else None,
        # fraction of the bound spent on useful model FLOPs
        "roofline_fraction": (mf / PEAK_FLOPS_BF16 / bound)
        if (mf and bound > 0) else None,
    }
    return out


def main(fast: bool = False) -> list:
    rows = []
    if not os.path.exists(DRYRUN_PATH):
        rows.append(csv_row("roofline/missing", 0.0,
                            f"run repro.launch.dryrun first ({DRYRUN_PATH})"))
        return rows
    with open(DRYRUN_PATH) as f:
        recs = json.load(f)
    table = {}
    for rec in recs:
        key = f"{rec['arch']}/{rec['shape']}/{rec['mesh']}"
        if "skipped" in rec:
            table[key] = {"skipped": rec["skipped"]}
            rows.append(csv_row(f"roofline/{key}", 0.0, "skipped_by_rule"))
            continue
        if "error" in rec:
            table[key] = {"error": rec["error"]}
            rows.append(csv_row(f"roofline/{key}", 0.0, "ERROR"))
            continue
        t = roofline_terms(rec)
        table[key] = t
        rf = t["roofline_fraction"]
        rows.append(csv_row(
            f"roofline/{key}", t["roofline_bound_s"] * 1e6,
            f"dom={t['dominant']};frac={rf:.3f}" if rf is not None
            else f"dom={t['dominant']}"))
    save_artifact("roofline", table)
    return rows


if __name__ == "__main__":
    print("\n".join(main()))
