"""Tenant-fleet throughput gate (DESIGN.md §4.6).

    PYTHONPATH=src python -m benchmarks.tenant_fleet [--fast]

The §4.6 tenant axis stacks T logical filters into one ``(T, ...)`` state
and routes a mixed batch through ONE vmapped launch. This emitter measures
that launch against the obvious alternative — a per-tenant Python loop
over T independent single-filter engines, each fed its own pre-partitioned
padded slice (partitioning cost is paid OUTSIDE the timed region, so the
loop is flattered) — at T in {1, 16, 256}. The acceptance bar, validated
by ``scripts/bench_check.py --tenants``: at T=256 the one-launch fleet
must hold >= 2x the loop's elems/s, with zero slot overflow and the
one-dispatch stream contract (stream_cache == 1) intact.

Emits ``BENCH_tenants.json`` at the repo root in the same baseline/current
shape as the other BENCH artifacts. ``--fast`` trims repetitions, never
the workload.
"""

from __future__ import annotations

import dataclasses
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Dedup, DedupConfig
from repro.core.fleet import FleetDedup

from .common import csv_row, save_artifact, stream

BENCH_PATH = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                          "BENCH_tenants.json"))
TENANT_COUNTS = (1, 16, 256)
GATE_T = 256                # the fleet-vs-loop gate applies at this T
GATE_SPEEDUP = 2.0          # one launch >= 2x the per-tenant Python loop

BATCH = 1024
STEPS = 8                   # N = BATCH * STEPS keys per measurement


def _cfg(t: int) -> DedupConfig:
    # memory_bits is PER TENANT (the stacked axis broadcasts the filter)
    return DedupConfig(variant="rlbsbf", memory_bits=1 << 14, k=4,
                       batch_size=BATCH, n_tenants=t, seed=7).validate()


def _capacity(t: int) -> int:
    # 4x the mean per-tenant occupancy of a uniform batch, floor 64 — deep
    # enough that uniform traffic never overflows a slot row (recorded and
    # gated at zero), shallow enough that the fleet pays a real padding tax
    return min(BATCH, max(64, 4 * BATCH // t))


def _workload(t: int, n: int):
    keys, _truth = stream(n, 0.6, seed=9)
    tens = np.random.default_rng(13).integers(0, t, n).astype(np.int32)
    return np.asarray(keys).astype(np.uint32), tens


def _measure_fleet(cfg: DedupConfig, capacity: int, keys: np.ndarray,
                   tens: np.ndarray, reps: int) -> dict:
    fleet = FleetDedup(cfg, capacity=capacity)
    jkeys, jtens = jnp.asarray(keys), jnp.asarray(tens)
    n = int(jkeys.shape[0])
    _st, dup, ovf = fleet.run_stream(fleet.init(), jkeys, jtens)  # compile
    np.asarray(dup)
    overflow = int(np.asarray(ovf).sum())
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        _st, dup, _ovf = fleet.run_stream(fleet.init(), jkeys, jtens)
        np.asarray(dup)
        best = min(best, time.perf_counter() - t0)
    return {"eps": n / best, "us_per_elem": best / n * 1e6,
            "overflow": overflow,
            "stream_cache": fleet.stream_cache_size()}


def _measure_loop(cfg: DedupConfig, capacity: int, keys: np.ndarray,
                  tens: np.ndarray, reps: int) -> dict:
    """T independent single-filter engines driven from Python — the fleet's
    counterfactual. One shared ``Dedup`` (so every tenant reuses ONE
    compiled trace) and a pre-partitioned padded schedule built outside the
    timed region: the loop pays only its irreducible cost, T dispatches
    per step."""
    t = cfg.n_tenants
    base = dataclasses.replace(cfg, n_tenants=1).validate()
    eng = Dedup(base)
    n = len(keys)
    sched = []
    for s in range(0, n, BATCH):
        kb, tb = keys[s:s + BATCH], tens[s:s + BATCH]
        per = []
        for tt in range(t):
            sel = kb[tb == tt][:capacity]
            kp = np.zeros(capacity, np.uint32)
            kp[:len(sel)] = sel
            vm = np.zeros(capacity, bool)
            vm[:len(sel)] = True
            per.append((jnp.asarray(kp), jnp.asarray(vm)))
        sched.append(per)
    init_states = [eng.init() for _ in range(t)]

    def run_once():
        sts = list(init_states)             # process_padded never donates
        res = None
        for per in sched:
            for tt, (kp, vm) in enumerate(per):
                sts[tt], res = eng.process_padded(sts[tt], kp, vm,
                                                  width=capacity)
        np.asarray(res.dup)                 # sync

    run_once()                              # compile
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        run_once()
        best = min(best, time.perf_counter() - t0)
    return {"eps": n / best, "us_per_elem": best / n * 1e6,
            "dispatches_per_step": t}


def measure_tenant_fleet(fast: bool = True) -> dict:
    reps = 2 if fast else 3
    out = {}
    for t in TENANT_COUNTS:
        cfg, cap = _cfg(t), _capacity(t)
        keys, tens = _workload(t, BATCH * STEPS)
        fleet = _measure_fleet(cfg, cap, keys, tens, reps)
        loop = _measure_loop(cfg, cap, keys, tens, reps)
        out[f"T_{t}"] = {"fleet": fleet, "loop": loop,
                         "speedup": fleet["eps"] / loop["eps"],
                         "capacity": cap}
    return out


def write_tenant_artifact(current: dict, meta: dict) -> str:
    prev = {}
    if os.path.exists(BENCH_PATH):
        with open(BENCH_PATH) as f:
            prev = json.load(f)
    baseline = prev.get("baseline")
    if baseline is None:
        baseline = dict(current, baseline_seeded_from_current=True)
    doc = {"schema": 1, "baseline": baseline, "current": current,
           "meta": meta}
    with open(BENCH_PATH, "w") as f:
        json.dump(doc, f, indent=1)
    return BENCH_PATH


def main(fast: bool = False) -> list:
    out = measure_tenant_fleet(fast=fast)
    rows = []
    for name, rec in out.items():
        rows.append(csv_row(
            f"tenants/{name}", rec["fleet"]["us_per_elem"],
            f"fleet_eps={rec['fleet']['eps']:.0f} "
            f"loop_eps={rec['loop']['eps']:.0f} "
            f"speedup={rec['speedup']:.2f}x "
            f"overflow={rec['fleet']['overflow']}"))
    save_artifact("tenant_fleet", out)
    path = write_tenant_artifact(
        out, meta={"fast": fast, "backend": jax.default_backend(),
                   "captured": time.strftime("%Y-%m-%d")})
    rows.append(csv_row("tenants/artifact", 0.0, path))
    return rows


if __name__ == "__main__":
    fast = "--fast" in __import__("sys").argv
    print("\n".join(main(fast=fast)))
