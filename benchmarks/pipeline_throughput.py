"""Pipelined sharded ingest sweep (DESIGN §4.5): ``pipeline=True`` (the
double-buffered, count-dispatched, owner-compacted scan) vs ``pipeline=False``
(the serial route -> all_to_all -> step -> all_to_all body) at 1, 2, 4 and 8
simulated host devices, static and elastic, plus the bit-parity digest grid.

    PYTHONPATH=src python -m benchmarks.pipeline_throughput [--fast]

Throughput rows: the paper-scale static row (swbf, the windowed counter
engine the paper's unbounded-stream claim leans on: global batch 16384,
memory 2^20 bits, window 8) and an elastic row (same engine behind the
bucket router) — each timed pipelined AND serial through the one-dispatch
``run_stream`` scan. The acceptance gate (validated by
``scripts/bench_check.py --pipeline``) requires pipelined >= 1.25x serial
elems/s at 8 devices on the static paper-scale row.

Parity grid: for every (backend in {jnp, pallas}, elastic in {off, on},
kernel_accumulate in {off, on}) cell, the dup-verdict sha256 digest of the
same stream at 8 devices and at 1 device, pipelined and serial. Required
bit-identities (all deterministic, no tolerance):

  * pipelined == serial at EVERY device count, every cell (§4.5 —
    the pipeline changes schedule, not math);
  * kernel_accumulate on == off, every cell (§3.9 — the accumulation
    mode changes where reduction happens, not what is reduced);
  * elastic 8-device == 1-device oracle (§4.4 — placement, not math).

The static rows are NOT digest-compared across device counts: static
sharding re-hashes keys into per-shard filters of s/n_shards bits, so the
8-device and 1-device filters are different hash spaces by design (their
equivalence is statistical — BENCH_sharded.json's FPR/FNR rows — not
bitwise; §4).

Each device count runs in its own subprocess
(``xla_force_host_platform_device_count`` is locked at first jax init).
Emits ``BENCH_pipeline.json`` in the frozen-baseline/current shape shared
by the other BENCH artifacts. Caveat: simulated devices share one CPU, so
the pipelined speedup measured here comes from the protocol (one fewer
all_to_all, no tag sort, owner-side step compaction) — the dispatch/compute
OVERLAP the double-buffered carry exposes needs real async collectives and
is captured by the hillclimb flag sweep on real hardware instead.
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

BENCH_PATH = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                          "BENCH_pipeline.json"))
DEVICE_COUNTS = (1, 2, 4, 8)
GATE_DEVICES = 8
GATE_SPEEDUP = 1.25
PARITY_CELLS = tuple(
    {"backend": backend, "elastic": elastic, "accum": accum}
    for backend in ("jnp", "pallas")
    for elastic in (False, True)
    for accum in (False, True))


def _paper_cfg(elastic: bool):
    from repro.core import DedupConfig
    kw = dict(rebalance_buckets=16, rebalance_threshold=1.25) if elastic \
        else {}
    return DedupConfig.for_variant(
        "swbf", window=8, memory_bits=1 << 20, batch_size=16384,
        packed=True, **kw)


def measure(devices: int, fast: bool) -> dict:
    """Runs inside the subprocess: paper-scale swbf throughput, pipelined
    vs serial, static and elastic, at the locked device count."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.compat import set_mesh
    from repro.dedup import ShardedDedup, ShardedDedupConfig

    assert len(jax.devices()) == devices, (len(jax.devices()), devices)
    n = 1 << (18 if fast else 19)
    mesh = jax.make_mesh((devices, 1), ("data", "model"))
    keys = jnp.asarray(np.random.default_rng(9).integers(
        0, 1 << 21, n).astype(np.uint32))
    out = {"devices": devices, "n": n, "batch": 16384}
    for mode, elastic in (("static", False), ("elastic", True)):
        cfg = _paper_cfg(elastic)
        rec = {}
        for tag, pipe in (("pipelined", True), ("serial", False)):
            sd = ShardedDedup(ShardedDedupConfig(
                base=cfg, pipeline=pipe,
                **({"capacity_factor": 16.0} if elastic else {})), mesh)
            with set_mesh(mesh):
                state, dup, ovf = sd.run_stream(sd.init(), keys)  # compile
                np.asarray(dup)
                best = float("inf")
                for _ in range(3):
                    t0 = time.perf_counter()
                    _st, dup, ovf = sd.run_stream(sd.init(), keys)
                    np.asarray(dup)
                    best = min(best, time.perf_counter() - t0)
            rec[tag] = {"eps": n / best, "us_per_elem": best / n * 1e6,
                        "overflow": int(np.asarray(ovf).sum()),
                        "stream_cache": sd.stream_cache_size()}
        rec["speedup"] = rec["pipelined"]["eps"] / rec["serial"]["eps"]
        out[mode] = rec
    return out


def measure_parity(devices: int, backend: str) -> dict:
    """Runs inside the subprocess: the digest grid at one device count and
    backend — (elastic, kernel_accumulate, pipeline) -> dup sha256 over a
    fixed range-skewed stream (skew exercises the elastic monitor; the
    static rows hash-route the identical keys). Sizes are small: the pallas
    rows run the fused kernel in interpret mode off-TPU."""
    import dataclasses
    import hashlib

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.compat import set_mesh
    from repro.core import DedupConfig
    from repro.data.streams import zipf_range_stream
    from repro.dedup import ShardedDedup, ShardedDedupConfig

    assert len(jax.devices()) == devices, (len(jax.devices()), devices)
    n, batch, mem, nb = 1 << 12, 512, 1 << 15, 8
    mesh = jax.make_mesh((devices, 1), ("data", "model"))
    keys, _ = zipf_range_stream(n, universe=1 << 11, a=1.2, seed=11)
    jkeys = jnp.asarray(keys)
    out = {"devices": devices, "backend": backend, "n": n, "batch": batch}
    for elastic in (False, True):
        kw = dict(rebalance_buckets=nb, rebalance_threshold=1.3) if elastic \
            else {}
        cfg = DedupConfig.for_variant(
            "swbf", window=3, memory_bits=mem, batch_size=batch,
            packed=True, backend=backend, **kw)
        for accum in (False, True):
            acfg = dataclasses.replace(cfg, kernel_accumulate=accum)
            for pipe in (True, False):
                sd = ShardedDedup(ShardedDedupConfig(
                    base=acfg, pipeline=pipe,
                    **({"capacity_factor": float(nb)} if elastic else {})),
                    mesh)
                with set_mesh(mesh):
                    st, dup, ovf = sd.run_stream(sd.init(), jkeys)
                key = (f"{'elastic' if elastic else 'static'}"
                       f"/accum_{'on' if accum else 'off'}"
                       f"/{'pipelined' if pipe else 'serial'}")
                out[key] = {
                    "digest": hashlib.sha256(
                        np.asarray(dup).tobytes()).hexdigest(),
                    "overflow": int(np.asarray(ovf).sum()),
                    "n_rebalances": (
                        int(np.asarray(st.router.n_rebalances))
                        if st.router is not None else None),
                }
    return out


def _worker_main(argv) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--worker", type=int, required=True)
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--parity", action="store_true")
    ap.add_argument("--backend", default="jnp")
    args = ap.parse_args(argv)
    if args.parity:
        print(json.dumps(measure_parity(args.worker, args.backend)))
    else:
        print(json.dumps(measure(args.worker, fast=args.fast)))
    return 0


# ------------------------------------------------------------------ parent
def _spawn(devices: int, fast: bool, extra=()) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = "src" + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    cmd = ([sys.executable, "-m", "benchmarks.pipeline_throughput",
            "--worker", str(devices)] + (["--fast"] if fast else [])
           + list(extra))
    out = subprocess.run(cmd, capture_output=True, text=True, env=env,
                         cwd=os.path.dirname(os.path.dirname(
                             os.path.abspath(__file__))))
    if out.returncode != 0:
        return {"devices": devices, "error": out.stderr[-2000:]}
    return json.loads(out.stdout.strip().splitlines()[-1])


def _grid_parity(grid: dict) -> dict:
    """Reduce the raw digest grid to the three §4.5/§3.9/§4.4 bit-identity
    claims. Returns per-claim booleans plus the list of broken cells."""
    broken = []

    def dig(devices, backend, cell, pipe):
        rec = grid.get((devices, backend), {})
        key = (f"{'elastic' if cell['elastic'] else 'static'}"
               f"/accum_{'on' if cell['accum'] else 'off'}"
               f"/{'pipelined' if pipe else 'serial'}")
        return rec.get(key, {}).get("digest")

    pipe_ok = accum_ok = oracle_ok = True
    for cell in PARITY_CELLS:
        backend = cell["backend"]
        for devices in (GATE_DEVICES, 1):
            a = dig(devices, backend, cell, True)
            b = dig(devices, backend, cell, False)
            if not a or a != b:
                pipe_ok = False
                broken.append(f"pipelined != serial @ {devices}dev {cell}")
            twin = dict(cell, accum=not cell["accum"])
            c = dig(devices, backend, twin, True)
            if not a or a != c:
                accum_ok = False
                broken.append(f"accum on != off @ {devices}dev {cell}")
        if cell["elastic"]:
            a8 = dig(GATE_DEVICES, backend, cell, True)
            a1 = dig(1, backend, cell, True)
            if not a8 or a8 != a1:
                oracle_ok = False
                broken.append(f"elastic != 1-device oracle @ {cell}")
    return {"pipelined_eq_serial": pipe_ok, "accum_invariant": accum_ok,
            "elastic_eq_oracle": oracle_ok,
            "ok": pipe_ok and accum_ok and oracle_ok, "broken": broken}


def write_pipeline_artifact(current: dict, meta: dict) -> str:
    prev = {}
    if os.path.exists(BENCH_PATH):
        with open(BENCH_PATH) as f:
            prev = json.load(f)
    baseline = prev.get("baseline")
    # only a fully-successful capture (every device count measured, parity
    # grid complete) may freeze the anchor
    ok = (all("error" not in current.get(f"devices_{d}", {})
              for d in DEVICE_COUNTS)
          and current.get("parity", {}).get("ok"))
    if baseline is None and ok:
        baseline = dict(current, baseline_seeded_from_current=True)
    doc = {"schema": 1, "baseline": baseline, "current": current,
           "meta": meta}
    with open(BENCH_PATH, "w") as f:
        json.dump(doc, f, indent=1)
    return BENCH_PATH


def main(fast: bool = False) -> list:
    from .common import csv_row, save_artifact

    current = {}
    for d in DEVICE_COUNTS:
        rec = _spawn(d, fast)
        current[f"devices_{d}"] = rec
        if "error" in rec:
            print(f"[pipeline] devices={d} FAILED: {rec['error']}",
                  file=sys.stderr)
        else:
            st, el = rec["static"], rec["elastic"]
            print(f"[pipeline] devices={d}: static "
                  f"{st['serial']['eps']:.0f} -> {st['pipelined']['eps']:.0f}"
                  f" eps ({st['speedup']:.2f}x), elastic "
                  f"{el['serial']['eps']:.0f} -> {el['pipelined']['eps']:.0f}"
                  f" eps ({el['speedup']:.2f}x)")

    grid = {}
    for backend in ("jnp", "pallas"):
        for d in (GATE_DEVICES, 1):
            rec = _spawn(d, fast, ["--parity", "--backend", backend])
            grid[(d, backend)] = rec
            if "error" in rec:
                print(f"[pipeline] parity devices={d} backend={backend} "
                      f"FAILED: {rec['error']}", file=sys.stderr)
    current["parity_grid"] = {
        f"devices_{d}/{backend}": rec
        for (d, backend), rec in grid.items()}
    current["parity"] = _grid_parity(grid)
    gate_rec = current.get(f"devices_{GATE_DEVICES}", {}).get("static", {})
    current["gate"] = {
        "devices": GATE_DEVICES, "required_speedup": GATE_SPEEDUP,
        "speedup": gate_rec.get("speedup"),
        "parity_ok": current["parity"]["ok"],
    }
    print(f"[pipeline] gate: {gate_rec.get('speedup', 0):.2f}x "
          f"(>= {GATE_SPEEDUP}x required) at {GATE_DEVICES} devices, "
          f"parity={'OK' if current['parity']['ok'] else 'BROKEN'}")

    rows = []
    for d in DEVICE_COUNTS:
        rec = current.get(f"devices_{d}", {})
        if "static" in rec:
            rows.append(csv_row(
                f"pipeline/devices_{d}",
                1e6 / rec["static"]["pipelined"]["eps"],
                f"speedup={rec['static']['speedup']:.2f}x"))
        else:
            rows.append(csv_row(f"pipeline/devices_{d}", 0.0, "ERROR"))
    save_artifact("pipeline", {k: v for k, v in current.items()
                               if k != "parity_grid"})
    import jax
    path = write_pipeline_artifact(
        current, meta={"fast": fast, "backend": jax.default_backend(),
                       "captured": time.strftime("%Y-%m-%d"),
                       "note": "simulated host devices share one CPU; "
                               "pallas parity rows run in interpret mode"})
    rows.append(csv_row("pipeline/artifact", 0.0, path))
    return rows


if __name__ == "__main__":
    if "--worker" in sys.argv:
        raise SystemExit(_worker_main(sys.argv[1:]))
    print("\n".join(main(fast="--fast" in sys.argv)))
