"""Sharded-dedup scaling sweep: elems/s of ``ShardedDedup.run_stream`` at
1, 2, 4 and 8 simulated host devices — for the packed 1-bit RLBSBF engine
AND the SBF counter-plane engine (DESIGN.md §3.6), so the sharded artifact
covers a counter variant.

    PYTHONPATH=src python -m benchmarks.sharded_scaling [--fast]
    PYTHONPATH=src python -m benchmarks.sharded_scaling --rebalance [--fast]

``--rebalance`` runs the elastic-rebalance sweep instead (DESIGN.md §4.4):
a zipf(1.2) range-skewed stream over 8 simulated devices, rebalance-on vs
rebalance-off — per-shard load spread (max/mean ratio), throughput,
rebalance count — plus dup-verdict bit-parity against a 1-device oracle
holding all buckets, on the jnp AND (at reduced size — interpret mode)
pallas backends. Emits ``BENCH_rebalance.json``, validated by
``scripts/bench_check.py --rebalance``.

Each device count runs in its OWN subprocess because
``xla_force_host_platform_device_count`` is locked at the first jax init —
the parent never touches multi-device state. Every worker ingests the same
stream through the one-dispatch sharded scan (state donated, DESIGN.md §4)
and reports elems/s, overflow and the compile-cache size (must be 1 per
engine: the scan compiles once per stream length). The SBF rows land under
a ``"sbf"`` sub-record of each ``devices_N`` entry (the top-level fields
stay the RLBSBF numbers the frozen baseline already anchors).

Emits ``BENCH_sharded.json`` at the repo root, in the same
baseline/current shape as ``BENCH_throughput.json``: ``baseline`` is frozen
at first capture (the regression anchor ``scripts/bench_check.py --sharded``
validates against), ``current`` is refreshed on every run.

Caveat for reading the numbers: simulated host devices share one CPU, so
wall-clock does not model real multi-chip scaling — the sweep exists to (a)
prove the sharded path executes at every device count and (b) anchor a
trajectory for the per-device all-to-all + step cost. TPU-side scaling is
modeled in §Roofline from the compiled HLO instead.
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

BENCH_PATH = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                          "BENCH_sharded.json"))
REBALANCE_PATH = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                              "BENCH_rebalance.json"))
DEVICE_COUNTS = (1, 2, 4, 8)
REBALANCE_DEVICES = 8


# ------------------------------------------------------------------ worker
def measure(devices: int, fast: bool = True) -> dict:
    """Runs inside the subprocess (device count already locked via env)."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.compat import set_mesh
    from repro.core import DedupConfig
    from repro.dedup import ShardedDedup, ShardedDedupConfig

    assert len(jax.devices()) == devices, (len(jax.devices()), devices)
    n = 1 << (18 if fast else 21)
    batch = 8192
    mesh = jax.make_mesh((devices, 1), ("data", "model"))
    keys = np.random.default_rng(9).integers(
        0, n, n).astype(np.uint32)
    jkeys = jnp.asarray(keys)

    def sweep(cfg):
        sd = ShardedDedup(ShardedDedupConfig(base=cfg), mesh)
        with set_mesh(mesh):
            # compile at full shape, then time the cached scan (best-of-3:
            # shared-CPU wall clock jitters far more than the engine does)
            state, dup, ovf = sd.run_stream(sd.init(), jkeys)
            np.asarray(dup)
            best = float("inf")
            for _ in range(3):
                t0 = time.perf_counter()
                _st, dup, ovf = sd.run_stream(sd.init(), jkeys)
                np.asarray(dup)
                best = min(best, time.perf_counter() - t0)
        return {
            "eps": n / best, "us_per_elem": best / n * 1e6,
            "overflow": int(np.asarray(ovf).sum()),
            "stream_cache": sd.stream_cache_size(),
        }

    rec = sweep(DedupConfig.for_variant("rlbsbf", memory_bits=1 << 20,
                                        batch_size=batch, packed=True))
    rec.update(devices=devices, n=n, batch=batch)
    # the counter variant on the same mesh: SBF rides the plane layout
    # through the identical sharded scan (DESIGN §3.6)
    rec["sbf"] = sweep(DedupConfig.for_variant(
        "sbf", memory_bits=1 << 20, batch_size=batch, layout="planes"))
    return rec


# ------------------------------------------------------ rebalance worker
def measure_rebalance(devices: int, fast: bool, backend: str) -> dict:
    """One elastic-rebalance measurement (inside the subprocess): the
    range-skewed zipf(1.2) stream through the elastic sharded scan,
    rebalance-on (threshold 1.25) and — on the multi-device run —
    rebalance-off (threshold 0, buckets static), with per-shard load
    spread, throughput and a dup-verdict digest for the parity check.
    ``capacity_factor == n_buckets`` makes the dispatch lossless (zero
    overflow), which is what makes bit-parity across device counts a fair
    assertion rather than luck (DESIGN §4.4)."""
    import hashlib

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.compat import set_mesh
    from repro.core import DedupConfig
    from repro.data.streams import zipf_range_stream
    from repro.dedup import ShardedDedup, ShardedDedupConfig

    assert len(jax.devices()) == devices, (len(jax.devices()), devices)
    if backend == "pallas":        # interpret mode off-TPU: tiny, 1 timed run
        n, batch, mem, nb, reps = 1 << 12, 512, 1 << 15, 16, 1
    elif fast:
        n, batch, mem, nb, reps = 1 << 16, 2048, 1 << 18, 16, 3
    else:
        n, batch, mem, nb, reps = 1 << 18, 4096, 1 << 20, 32, 3
    mesh = jax.make_mesh((devices, 1), ("data", "model"))
    keys, _ = zipf_range_stream(n, universe=max(n // 2, 1 << 10), a=1.2,
                                seed=11)
    jkeys = jnp.asarray(keys)
    kw = dict(packed=True, backend="pallas") if backend == "pallas" else {}
    out = {"devices": devices, "n": n, "batch": batch, "buckets": nb,
           "backend": backend}
    modes = (("on", 1.25), ("off", 0.0)) if devices > 1 else (("on", 1.25),)
    for tag, thr in modes:
        cfg = DedupConfig.for_variant(
            "rlbsbf", memory_bits=mem, batch_size=batch,
            rebalance_buckets=nb, rebalance_threshold=thr, **kw)
        sd = ShardedDedup(
            ShardedDedupConfig(base=cfg, capacity_factor=float(nb)), mesh)
        with set_mesh(mesh):
            state, dup, ovf = sd.run_stream(sd.init(), jkeys)   # compile
            np.asarray(dup)
            best = float("inf")
            for _ in range(reps):
                t0 = time.perf_counter()
                state, dup, ovf = sd.run_stream(sd.init(), jkeys)
                np.asarray(dup)
                best = min(best, time.perf_counter() - t0)
        load = np.asarray(state.load)
        shard_load = load.sum(axis=tuple(range(1, load.ndim)))
        out[tag] = {
            "eps": n / best,
            "load_ratio": float(shard_load.max()
                                / max(shard_load.mean(), 1e-9)),
            "shard_load": shard_load.tolist(),
            "n_rebalances": int(np.asarray(state.router.n_rebalances)),
            "overflow": int(np.asarray(ovf).sum()),
            "stream_cache": sd.stream_cache_size(),
            "digest": hashlib.sha256(np.asarray(dup).tobytes()).hexdigest(),
        }
    return out


def _worker_main(argv) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--worker", type=int, required=True)
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--rebalance", action="store_true")
    ap.add_argument("--backend", default="jnp")
    args = ap.parse_args(argv)
    if args.rebalance:
        print(json.dumps(measure_rebalance(args.worker, fast=args.fast,
                                           backend=args.backend)))
    else:
        print(json.dumps(measure(args.worker, fast=args.fast)))
    return 0


# ------------------------------------------------------------------ parent
def _spawn(devices: int, fast: bool, extra=()) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = "src" + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    cmd = ([sys.executable, "-m", "benchmarks.sharded_scaling",
            "--worker", str(devices)] + (["--fast"] if fast else [])
           + list(extra))
    out = subprocess.run(cmd, capture_output=True, text=True, env=env,
                         cwd=os.path.dirname(os.path.dirname(
                             os.path.abspath(__file__))))
    if out.returncode != 0:
        return {"devices": devices, "error": out.stderr[-2000:]}
    return json.loads(out.stdout.strip().splitlines()[-1])


def write_sharded_artifact(current: dict, meta: dict) -> str:
    prev = {}
    if os.path.exists(BENCH_PATH):
        with open(BENCH_PATH) as f:
            prev = json.load(f)
    baseline = prev.get("baseline")
    # the frozen anchor only ever absorbs SUCCESSFUL records: a failed
    # subprocess must not permanently hollow out a device count's baseline —
    # missing counts are backfilled by the next run that measures them, and
    # engine sub-records added later (e.g. the SBF counter rows) backfill
    # into already-frozen device entries the same way
    ok = {k: v for k, v in current.items() if "eps" in v}
    if baseline is None:
        baseline = dict(ok, baseline_seeded_from_current=True)
    else:
        for k, v in ok.items():
            base_rec = baseline.setdefault(k, dict(v, baseline_backfilled=True))
            if "sbf" in v and "sbf" not in base_rec:
                base_rec["sbf"] = dict(v["sbf"], baseline_backfilled=True)
    doc = {"schema": 1, "baseline": baseline, "current": current,
           "meta": meta}
    with open(BENCH_PATH, "w") as f:
        json.dump(doc, f, indent=1)
    return BENCH_PATH


def write_rebalance_artifact(current: dict, meta: dict) -> str:
    prev = {}
    if os.path.exists(REBALANCE_PATH):
        with open(REBALANCE_PATH) as f:
            prev = json.load(f)
    baseline = prev.get("baseline")
    # only a fully-successful capture may freeze the anchor: a failed
    # backend record must not become a permanent baseline
    ok = all("error" not in current.get(b, {}) for b in ("jnp", "pallas"))
    if baseline is None and ok:
        baseline = dict(current, baseline_seeded_from_current=True)
    doc = {"schema": 1, "baseline": baseline, "current": current,
           "meta": meta}
    with open(REBALANCE_PATH, "w") as f:
        json.dump(doc, f, indent=1)
    return REBALANCE_PATH


def main_rebalance(fast: bool = False) -> list:
    """The §4.4 acceptance sweep: per-backend subprocess pairs — the
    8-device run (rebalance on AND off) and the 1-device all-buckets oracle
    — digest-compared for bit-parity, written to BENCH_rebalance.json."""
    from .common import csv_row, save_artifact

    current = {}
    for backend in ("jnp", "pallas"):
        multi = _spawn(REBALANCE_DEVICES, fast,
                       ["--rebalance", "--backend", backend])
        oracle = _spawn(1, fast, ["--rebalance", "--backend", backend])
        if "error" in multi or "error" in oracle:
            err = multi.get("error") or oracle.get("error")
            print(f"[rebalance] backend={backend} FAILED: {err}",
                  file=sys.stderr)
            current[backend] = {"error": err}
            continue
        rec = dict(multi, oracle=oracle["on"])
        rec["parity"] = (multi["on"]["digest"] == multi["off"]["digest"]
                         == oracle["on"]["digest"])
        current[backend] = rec
        on, off = multi["on"], multi["off"]
        print(f"[rebalance] {backend}: load max/mean "
              f"{off['load_ratio']:.2f} -> {on['load_ratio']:.2f} "
              f"({on['n_rebalances']} repartitions), "
              f"eps on/off {on['eps']:.0f}/{off['eps']:.0f}, "
              f"parity={'OK' if rec['parity'] else 'BROKEN'}")

    rows = []
    for backend, rec in current.items():
        if "on" in rec:
            rows.append(csv_row(
                f"rebalance/{backend}", 1e6 / rec["on"]["eps"],
                f"ratio {rec['off']['load_ratio']:.2f}->"
                f"{rec['on']['load_ratio']:.2f} parity={rec['parity']}"))
        else:
            rows.append(csv_row(f"rebalance/{backend}", 0.0, "ERROR"))
    save_artifact("rebalance", current)
    import jax
    path = write_rebalance_artifact(
        current, meta={"fast": fast, "backend": jax.default_backend(),
                       "captured": time.strftime("%Y-%m-%d"),
                       "note": "simulated host devices share one CPU; "
                               "pallas rows run in interpret mode"})
    rows.append(csv_row("rebalance/artifact", 0.0, path))
    return rows


def main(fast: bool = False) -> list:
    from .common import csv_row, save_artifact

    current = {}
    for d in DEVICE_COUNTS:
        rec = _spawn(d, fast)
        current[f"devices_{d}"] = rec
        if "error" in rec:
            print(f"[sharded_scaling] devices={d} FAILED: {rec['error']}",
                  file=sys.stderr)
        else:
            print(f"[sharded_scaling] devices={d}: {rec['eps']:.0f} elems/s "
                  f"overflow={rec['overflow']} cache={rec['stream_cache']}")
    ok = {k: v for k, v in current.items() if "eps" in v}
    if ok:
        base = current.get("devices_1", {}).get("eps")
        for k, v in ok.items():
            v["speedup_vs_1dev"] = (v["eps"] / base) if base else None

    rows = []
    for d in DEVICE_COUNTS:
        rec = current.get(f"devices_{d}", {})
        if "eps" in rec:
            rows.append(csv_row(f"sharded_scaling/devices_{d}",
                                1e6 / rec["eps"],
                                f"elems_per_s={rec['eps']:.0f}"))
        else:
            rows.append(csv_row(f"sharded_scaling/devices_{d}", 0.0, "ERROR"))
    save_artifact("sharded_scaling", current)
    import jax
    path = write_sharded_artifact(
        current, meta={"fast": fast, "backend": jax.default_backend(),
                       "captured": time.strftime("%Y-%m-%d"),
                       "note": "simulated host devices share one CPU"})
    rows.append(csv_row("sharded_scaling/artifact", 0.0, path))
    return rows


if __name__ == "__main__":
    if "--worker" in sys.argv:
        raise SystemExit(_worker_main(sys.argv[1:]))
    fast = "--fast" in sys.argv
    if "--rebalance" in sys.argv:
        print("\n".join(main_rebalance(fast=fast)))
    else:
        print("\n".join(main(fast=fast)))
