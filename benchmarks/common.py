"""Shared benchmark machinery: run a variant over a stream, measure
FPR/FNR/load/convergence + wall-clock throughput."""

from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Dedup, DedupConfig
from repro.data.streams import controlled_distinct_stream

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments")


def run_stream_measured(cfg: DedupConfig, keys: np.ndarray,
                        truth: np.ndarray, n_windows: int = 20) -> dict:
    """Process the whole stream; returns rates + windowed curves + throughput."""
    d = Dedup(cfg)
    st = d.init()
    jkeys = jnp.asarray(keys)
    # one warm-up batch for jit, then timed full run
    _ = d.process(st, jkeys[:cfg.batch_size])
    t0 = time.perf_counter()
    st, dup = d.run_stream(st, jkeys)
    dup = np.asarray(dup)
    dt = time.perf_counter() - t0
    n = len(keys)
    fp = (dup & ~truth)
    fn = (~dup & truth)
    w = max(1, n // n_windows)
    curves = []
    for i in range(0, n - w + 1, w):
        sl = slice(i, i + w)
        nd = max(1, int((~truth[sl]).sum()))
        ndup = max(1, int(truth[sl].sum()))
        curves.append({"pos": i + w,
                       "fpr": float(fp[sl].sum() / nd),
                       "fnr": float(fn[sl].sum() / ndup)})
    return {
        "fpr": float(fp.sum() / max(1, (~truth).sum())),
        "fnr": float(fn.sum() / max(1, truth.sum())),
        "throughput_eps": n / dt,
        "us_per_elem": dt / n * 1e6,
        "elapsed_s": dt,
        "final_load_frac": float(np.asarray(st.load).sum() /
                                 (cfg.n_rows * cfg.s)),
        "curves": curves,
    }


_STREAM_CACHE: dict = {}


def stream(n: int, distinct: float, seed: int = 0):
    key = (n, distinct, seed)
    if key not in _STREAM_CACHE:
        _STREAM_CACHE[key] = controlled_distinct_stream(n, distinct, seed)
        if len(_STREAM_CACHE) > 6:
            _STREAM_CACHE.pop(next(iter(_STREAM_CACHE)))
    return _STREAM_CACHE[key]


def save_artifact(name: str, obj) -> str:
    os.makedirs(ART_DIR, exist_ok=True)
    path = os.path.join(ART_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(obj, f, indent=1)
    return path


def csv_row(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.3f},{derived}"
